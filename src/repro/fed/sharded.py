"""Sharded-cohort fused aggregation (DESIGN.md §6).

The FedNCV server estimator (Eq. 10-12) collapses to one weighted sum
g = sum_u w_u g_u over the (cohort, N) message stack, so its cost is pure
memory bandwidth.  This module shards that stack along the cohort dimension
over a 1-d device mesh: each device runs the fused weighted-sum kernel
(`ncv_weighted_sum` / the codec's fused dequantize variant) over *its local
slice only* — one HBM pass over 1/D of the stack — and the partial sums
meet in a single parameter-sized `psum`.

Exactness with unequal client weights: the coefficients w_u depend on
global scalar statistics of the sample counts (n = sum_v n_v and
t = sum_v n_v/(n - n_v)), so the (cohort,)-sized counts are all-gathered
(a few scalars — negligible next to the N-sized payload) and every device
computes the exact global coefficient vector, then slices its own block.
The returned aggregate is therefore bitwise the same estimator as the
single-device `ncv_aggregate`, up to f32 summation order.

Padding rule: when cohort % D != 0 the caller pads the stacks with
zero-weight rows (`pad_cohort`).  A padded slot carries n_u = 0, which
makes w_u = 0 *exactly* (see `ncv_coefficients`) and contributes nothing
to n or t — padding changes neither the estimator nor the stats.

Every function in this module that takes an `axis_name` must run inside
`jax.shard_map` (or `shard_map`-like manual-collective context) over that
axis; `fed/simulator.py` wraps the cohort section of its round in exactly
such a region when constructed with a mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rloo.rloo import ncv_coefficients


def shard_map_compat(f, mesh, in_specs, out_specs):
    """`jax.shard_map` (jax >= 0.6) / `jax.experimental.shard_map` (0.4.x)
    with replication checking off — the one API difference between the two
    is the name of that flag."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def pad_cohort(tree, n_devices: int):
    """Pad every leaf's leading (cohort) dim to a multiple of n_devices.

    Padded rows are zeros — combined with n_u = 0 sample counts they are
    exact no-ops for the aggregation (module docstring).  Returns the tree
    unchanged when the cohort already divides.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree
    c = leaves[0].shape[0]
    pad = (-c) % n_devices
    if pad == 0:
        return tree
    return jax.tree.map(
        lambda x: jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)), tree)


def padded_cohort_size(cohort: int, n_devices: int) -> int:
    return cohort + ((-cohort) % n_devices)


def local_weights(n_local, beta, axis_name):
    """Exact per-client coefficients for this device's cohort slice.

    Runs inside shard_map: all-gathers the (cohort,) sample counts (scalar
    traffic), computes the *global* `ncv_coefficients` so unequal client
    weights stay exact, and slices the local block by `axis_index`.
    """
    n_all = jax.lax.all_gather(n_local, axis_name, tiled=True)   # (C_p,)
    w_all = ncv_coefficients(n_all, beta)
    i = jax.lax.axis_index(axis_name)
    c_loc = n_local.shape[0]
    return jax.lax.dynamic_slice_in_dim(w_all, i * c_loc, c_loc)


def sharded_aggregate(stack_local, n_local, beta=1.0, *, axis_name: str,
                      codec=None, use_pallas: bool | None = None):
    """Eq. 10-12 over a cohort-sharded stack: local fused pass + one psum.

    stack_local: this device's slice — a dense (C_loc, N) f32 array when
    `codec` is None, else the codec's stacked wire dict with (C_loc, ...)
    leaves.  n_local: (C_loc,) effective sample counts (0 for padded
    slots) — the raw shard sizes under uniform cohort selection, or the
    sampler's inverse-probability-scaled counts under non-uniform
    selection (repro.fed.sampling, DESIGN.md §8.2); the zero-padding rule
    applies to them identically.
    Returns (agg (N,) f32, ||agg||^2), replicated across the axis.  The
    norm is computed from the psum'd aggregate (partial norms do not add
    across shards — cross terms), costing one extra N-read.
    """
    if use_pallas is None:
        from repro.kernels import default_interpret
        use_pallas = not default_interpret()
    w_local = local_weights(n_local, beta, axis_name)
    if codec is None or codec.name == "identity":
        g_local = stack_local if not isinstance(stack_local, dict) else \
            stack_local["v"].astype(jnp.float32)
        if use_pallas:
            from repro.kernels.rloo.rloo import ncv_weighted_sum
            partial, _ = ncv_weighted_sum(g_local, w_local, interpret=False)
        else:
            from repro.kernels.rloo.ref import ncv_weighted_sum_ref
            partial, _ = ncv_weighted_sum_ref(g_local, w_local)
    else:
        partial, _ = codec.weighted_sum(stack_local, w_local,
                                        use_pallas=use_pallas)
    agg = jax.lax.psum(partial, axis_name)
    return agg, jnp.sum(agg * agg)


def sharded_clipped_aggregate(stack_local, n_local, beta, clip_mult, *,
                              axis_name: str, codec=None,
                              use_pallas: bool | None = None):
    """The `norm_clip` robust aggregator over a cohort-sharded stack.

    Norm clipping is the one robust reduction that keeps the
    local-partial + one-psum shape: the clip threshold depends only on
    the (cohort,) *scalar* upload norms, so those are all-gathered
    together with the sample counts (DESIGN.md §9) — still negligible
    next to the N-sized payload — every device computes the identical
    global threshold tau = clip_mult * median(valid norms) and clip
    factors, folds its local factor block into the exact global Eq. 10-12
    coefficients, and the partial sums meet in the same single psum as
    `sharded_aggregate`.  Padded slots (n_u = 0) are excluded from the
    median and keep w_u = 0 exactly.

    Non-identity codecs are decoded locally first: clipping needs true
    f32 norms, and the clipped weighted sum no longer matches the fused
    dequantize-aggregate contraction.
    """
    if use_pallas is None:
        from repro.kernels import default_interpret
        use_pallas = not default_interpret()
    if codec is not None and codec.name != "identity":
        g_local = jax.vmap(codec.decode)(stack_local)     # (C_loc, N) f32
    else:
        g_local = stack_local if not isinstance(stack_local, dict) else \
            stack_local["v"]
    g_local = g_local.astype(jnp.float32)
    norms_local = jnp.sqrt(jnp.sum(g_local * g_local, axis=1))
    norms = jax.lax.all_gather(norms_local, axis_name, tiled=True)  # (C_p,)
    n_all = jax.lax.all_gather(n_local, axis_name, tiled=True)      # (C_p,)
    from repro.kernels.robust.ref import masked_median_1d
    tau = clip_mult * masked_median_1d(norms, n_all > 0)
    clip = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-12))
    w_all = ncv_coefficients(n_all, beta) * clip
    i = jax.lax.axis_index(axis_name)
    c_loc = n_local.shape[0]
    w_local = jax.lax.dynamic_slice_in_dim(w_all, i * c_loc, c_loc)
    if use_pallas:
        from repro.kernels.rloo.rloo import ncv_weighted_sum
        partial, _ = ncv_weighted_sum(g_local, w_local, interpret=False)
    else:
        from repro.kernels.rloo.ref import ncv_weighted_sum_ref
        partial, _ = ncv_weighted_sum_ref(g_local, w_local)
    agg = jax.lax.psum(partial, axis_name)
    return agg, jnp.sum(agg * agg)
