"""Event-driven round coordinator (DESIGN.md §12).

The `Coordinator` owns the serve loop's control plane: a `ClientQueue`
of simulated check-ins, a registered `AdmissionPolicy` deciding how many
to admit each round, and a deadline policy cutting stragglers at
`deadline_s` — and it drives the data plane (a `fed.Simulator`) one
round at a time, writing what it decided into the simulator's
"external" sampler/fault tables before each dispatch.

The estimator contract ("dropout is just another sampler"): the round
jit never learns the cohort came from a queue.  The coordinator writes

  * sampler state (idx, invp): the admitted cohort ids, padded to the
    static `FLConfig.cohort` shape, with the admission Horvitz-Thompson
    factor 1/(M q_u) per slot — q_u estimated from the per-client
    admission-frequency EMA, normalized so a uniform world yields
    invp == 1 exactly — and invp = 0 on padding slots;
  * fault state (alive, invp): the deadline cut — alive = 0 for
    stragglers and padding, invp = alive / s_u with the closed-form
    exponential survival s_u = 1 - exp(-deadline / mu_u)

and the existing §8/§9 machinery does the rest: HT weights into
Eq. 10-12, state-scatter gating, honest bytes_up, the all-dropped
guard.  Unbiasedness condition (§12.3): conditional on admission, the
deadline cut is independent thinning with known per-client survival
probability, so E[sum_u w_u invp_u g_u] recovers the admitted-cohort
estimator exactly; across rounds the admission EMA is a consistent
estimate of the realized inclusion rate, approaching the exact HT
correction as the trace mixes.

Pipelining: `FLConfig.staleness = K` issues the admitted cohort at
round r and applies it at round r+K (the simulator's depth-K ring);
`drain()` flushes the K in-flight cohorts with zero-admission bubble
rounds — what a graceful SIGINT shutdown calls before the final
checkpoint, so no issued work is lost.

Telemetry: queue_depth / admitted / rejected / cohort_size /
deadline_miss_frac are published through `emit.set_host_metrics` and
ride every streamed row (`tools/flwatch.py` renders and gates them).
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.serve import admission
from repro.serve.queue import ClientQueue

SERVE_SIDECAR = "serve_state.json"


class Coordinator:
    """Drive `sim` (sampler="external", fault="external") from `queue`.

    policy / policy_opts: registered `AdmissionPolicy` + its options.
    deadline_s:     T_round — admitted clients slower than this are cut
                    (and HT-reweighted; <= 0 disables the cut).
    target_round_s: the wall-clock budget the adaptive policy steers
                    toward (defaults to deadline_s).
    """

    def __init__(self, sim, queue: ClientQueue, policy: str = "fixed",
                 policy_opts: dict | None = None, deadline_s: float = 2.0,
                 target_round_s: float | None = None, ema: float = 0.1):
        fl = sim.fl
        if fl.sampler != "external" or fl.fault != "external":
            raise ValueError(
                "Coordinator needs FLConfig.make(sampler='external', "
                "sampler_opts={'ext_cohort': cohort}, fault='external', "
                "fault_opts={'ext_slots': cohort}) — the coordinator "
                f"writes those tables; got sampler={fl.sampler!r}, "
                f"fault={fl.fault!r}")
        if queue.m != fl.n_clients:
            raise ValueError(f"queue has {queue.m} clients but the "
                             f"simulator has {fl.n_clients}")
        self.sim, self.queue = sim, queue
        self.policy = admission.get_policy(policy)
        self.policy_opts = admission.resolve_opts(self.policy, policy_opts)
        self.pstate = self.policy.init(self.policy_opts)
        self.deadline_s = float(deadline_s)
        self.target_round_s = float(deadline_s if target_round_s is None
                                    else target_round_s)
        self.ema = float(ema)
        # admission-frequency EMA (the q_u estimate); uniform start
        self._freq = np.full((fl.n_clients,), fl.cohort / fl.n_clients,
                             np.float64)
        self._last_round_s = 0.0
        self.last_metrics: dict = {}

    # ------------------------------------------------------------------
    def _admission_invp(self, ids) -> np.ndarray:
        """HT factor 1/(M q_u) for the admitted ids, with q normalized
        over the population — a uniform world gives exactly 1.0."""
        w = np.maximum(self._freq, 1e-6)
        q = w / w.sum()
        return 1.0 / (self.sim.fl.n_clients * q[np.asarray(ids, np.int64)])

    def _write_tables(self, ids, alive, invp_admit, invp_deadline):
        """Install this round's cohort + HT tables into the simulator's
        external sampler/fault state (the only coordinator->jit channel)."""
        c = self.sim.fl.cohort
        idx = np.zeros((c,), np.int32)
        s_invp = np.zeros((c,), np.float32)
        f_alive = np.zeros((c,), np.float32)
        f_invp = np.zeros((c,), np.float32)
        n = len(ids)
        if n:
            idx[:n] = np.asarray(ids, np.int32)
            s_invp[:n] = np.asarray(invp_admit, np.float32)
            f_alive[:n] = np.asarray(alive, np.float32)
            f_invp[:n] = np.asarray(invp_deadline, np.float32)
        st = self.sim._get_state()
        st["sampler"] = dict(idx=jnp.asarray(idx),
                             invp=jnp.asarray(s_invp))
        st["faults"] = dict(alive=jnp.asarray(f_alive),
                            invp=jnp.asarray(f_invp))
        self.sim._set_state(st)

    # ------------------------------------------------------------------
    def step(self, *, admit_override: int | None = None) -> dict:
        """One served round: tick the queue, admit, cut stragglers,
        write the tables, dispatch the round.  Returns the round's diag
        dict merged with the queue/admission metrics."""
        fl = self.sim.fl
        checkins = self.queue.tick()
        stats = dict(queue_depth=self.queue.depth, cohort_max=fl.cohort,
                     last_round_s=self._last_round_s,
                     target_round_s=self.target_round_s)
        if admit_override is None:
            n_admit, self.pstate = self.policy.admit(
                self.policy_opts, self.pstate, stats)
        else:
            n_admit = admit_override
        ids = self.queue.admit(n_admit)
        n = len(ids)
        if n:
            if self.deadline_s > 0:
                lat = self.queue.latencies(ids)
                alive = (lat <= self.deadline_s).astype(np.float32)
                surv = self.queue.survival(ids, self.deadline_s)
                invp_deadline = alive / np.maximum(surv, 1e-9)
            else:
                alive = np.ones((n,), np.float32)
                invp_deadline = np.ones((n,), np.float32)
            invp_admit = self._admission_invp(ids)
            miss_frac = 1.0 - float(np.mean(alive))
        else:
            alive = invp_deadline = invp_admit = np.zeros((0,), np.float32)
            miss_frac = 0.0
        self._write_tables(ids, alive, invp_admit, invp_deadline)
        # consistent inclusion-rate estimate for the next rounds' HT factor
        ind = np.zeros_like(self._freq)
        if n:
            ind[np.asarray(ids, np.int64)] = 1.0
        self._freq = (1.0 - self.ema) * self._freq + self.ema * ind
        metrics = dict(queue_depth=float(stats["queue_depth"]),
                       checkins=float(checkins), admitted=float(n),
                       rejected=float(stats["queue_depth"] - n),
                       cohort_size=float(np.sum(alive)),
                       deadline_miss_frac=float(miss_frac))
        self.last_metrics = metrics
        if self.sim._emit is not None:
            self.sim._emit.set_host_metrics(metrics)
        import time
        t0 = time.perf_counter()
        diag = self.sim.run_round()
        self._last_round_s = time.perf_counter() - t0
        return dict(diag, **metrics)

    def drain(self) -> list[dict]:
        """Flush the pipeline: run `staleness` zero-admission rounds so
        every in-flight cohort's server half is applied (the new bubbles
        are all-dead no-ops).  Sync mode (K=0) drains instantly."""
        return [self.step(admit_override=0)
                for _ in range(self.sim.fl.staleness)]

    # ------------------------------------------------------------------
    # serve checkpointing: simulator checkpoint + coordinator sidecar
    # ------------------------------------------------------------------
    def save(self, directory: str, keep: int = 3):
        """`checkpoint.save_sim` (params/state/pending ring) plus a json
        sidecar with the control-plane state (queue trace, policy state,
        admission EMA), so a restart resumes the exact served trajectory."""
        from repro.checkpoint import ckpt
        ckpt.save_sim(directory, self.sim, keep=keep)
        sidecar = dict(
            round_idx=self.sim.round_idx,
            policy=self.policy.name,
            pstate=self.pstate,
            freq=self._freq.tolist(),
            last_round_s=self._last_round_s,
            queue=self.queue.state_dict())
        tmp = os.path.join(directory, SERVE_SIDECAR + ".tmp")
        with open(tmp, "w") as f:
            json.dump(sidecar, f)
        os.replace(tmp, os.path.join(directory, SERVE_SIDECAR))

    def restore(self, directory: str) -> dict:
        """Restore the simulator checkpoint and the coordinator sidecar
        (when present — a sim-only checkpoint restores the data plane
        and keeps the fresh control plane)."""
        from repro.checkpoint import ckpt
        meta = ckpt.restore_sim(directory, self.sim)
        path = os.path.join(directory, SERVE_SIDECAR)
        if os.path.exists(path):
            with open(path) as f:
                sidecar = json.load(f)
            if sidecar.get("policy") != self.policy.name:
                raise ValueError(
                    f"serve checkpoint was written with admission policy "
                    f"{sidecar.get('policy')!r} but the coordinator runs "
                    f"{self.policy.name!r}")
            self.pstate = sidecar["pstate"]
            self._freq = np.asarray(sidecar["freq"], np.float64)
            self._last_round_s = float(sidecar["last_round_s"])
            self.queue.load_state_dict(sidecar["queue"])
        return meta


def make_serve_config(base=None, **kw):
    """Convenience: an `FLConfig.make` pre-wired for the coordinator —
    sampler/fault forced to "external" with matching slot counts."""
    from repro.fed import FLConfig
    kw = dict(base or {}, **kw)
    cohort = int(kw.get("cohort", 10))
    kw["sampler"] = "external"
    kw["sampler_opts"] = dict(ext_cohort=cohort)
    kw["fault"] = "external"
    kw["fault_opts"] = dict(ext_slots=cohort)
    return FLConfig.make(**kw)
