"""Admission control for the serve coordinator (DESIGN.md §12.2).

An `AdmissionPolicy` decides, once per round, how many of the queued
check-ins to admit into the cohort — the seventh registered strategy
family, mirroring `FedMethod` / `CohortSampler` / `Aggregator` /
`FaultModel` / `Tracker` / `StateStore`: a frozen dataclass with
`options`/`defaults`/`validate` resolved by the same `resolve_opts`
contract (a typo'd knob raises at construction, never mid-serve).

The policy only picks a COUNT.  Which clients fill the slots (FIFO off
the queue), the deadline cut, and the Horvitz-Thompson bookkeeping that
keeps Eq. 10-12 unbiased all live in `serve.coordinator` — so a policy
cannot break the estimator, only change load.

`admit(opts, state, stats) -> (n_admit, state)` sees one stats dict:

    queue_depth     clients waiting after this round's check-ins
    cohort_max      FLConfig.cohort — the static jit cohort shape; the
                    effective cohort shrinks via dead padding slots
                    (exact no-ops, like the mesh zero-weight padding)
    last_round_s    wall-clock of the previous round (0.0 on the first)
    target_round_s  the deadline the coordinator is serving against

Policies:
  fixed         admit min(queue_depth, cohort_max) — the no-control
                baseline.
  token_bucket  classic rate limiter over check-ins: `tb_rate` tokens
                per round, burst capacity `tb_burst`; one admitted
                client spends one token.  Caps sustained admission rate
                regardless of queue pressure.
  adaptive      grow/shrink the effective cohort against the round
                deadline: a round slower than `target_round_s` shrinks
                the next cohort multiplicatively (`ad_shrink`), a round
                inside the deadline with queue pressure grows it
                additively (`ad_grow`) — AIMD, so the cohort hunts the
                largest size the deadline sustains.  Wall-clock-driven
                by construction, so served trajectories are NOT
                bit-reproducible across runs (fixed / token_bucket are).
"""
from __future__ import annotations

import dataclasses
import typing as tp


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """One admission strategy: `init` builds the (json-serializable)
    policy state, `admit` spends it once per round."""
    name: str
    admit: tp.Callable            # (opts, state, stats) -> (n, state)
    init: tp.Callable = staticmethod(lambda opts: {})
    options: tuple = ()
    defaults: dict = dataclasses.field(default_factory=dict)
    validate: tp.Callable | None = None
    description: str = ""


_REGISTRY: dict[str, AdmissionPolicy] = {}


def register_policy(policy: AdmissionPolicy, *,
                    overwrite: bool = False) -> AdmissionPolicy:
    """Register `policy` under `policy.name`; returns it for chaining."""
    if not overwrite and policy.name in _REGISTRY:
        raise ValueError(
            f"admission policy '{policy.name}' is already registered")
    if set(policy.defaults) - set(policy.options):
        raise ValueError(
            f"admission policy '{policy.name}' has defaults for undeclared "
            f"options: {sorted(set(policy.defaults) - set(policy.options))}")
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> AdmissionPolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown admission policy '{name}'; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_opts(policy: AdmissionPolicy, opts: dict | None) -> dict:
    """Merge user options over the policy's defaults, rejecting unknown
    names and bad values — the `FLConfig.make` option contract."""
    opts = dict(opts or {})
    bad = sorted(set(opts) - set(policy.options))
    if bad:
        raise TypeError(
            f"option(s) {bad} are not used by admission policy "
            f"'{policy.name}'; valid options: {sorted(policy.options)}")
    resolved = {**policy.defaults, **opts}
    if policy.validate is not None:
        policy.validate(resolved)
    return resolved


# ---------------------------------------------------------------------------
# fixed — admit as many as fit (no-control baseline)
# ---------------------------------------------------------------------------

def _fixed_admit(opts, state, stats):
    del opts
    return min(stats["queue_depth"], stats["cohort_max"]), state


register_policy(AdmissionPolicy(
    name="fixed",
    admit=_fixed_admit,
    description="admit min(queue_depth, cohort_max) every round "
                "(no-control baseline)",
))


# ---------------------------------------------------------------------------
# token_bucket — rate-limit admissions over check-ins
# ---------------------------------------------------------------------------

def _tb_init(opts):
    return dict(tokens=float(opts["tb_burst"]))


def _tb_admit(opts, state, stats):
    tokens = min(float(opts["tb_burst"]),
                 state["tokens"] + float(opts["tb_rate"]))
    n = min(stats["queue_depth"], stats["cohort_max"], int(tokens))
    return n, dict(state, tokens=tokens - n)


def _tb_validate(opts):
    if opts["tb_rate"] <= 0 or opts["tb_burst"] <= 0:
        raise ValueError("tb_rate and tb_burst must be > 0")


register_policy(AdmissionPolicy(
    name="token_bucket",
    admit=_tb_admit,
    init=_tb_init,
    options=("tb_rate", "tb_burst"),
    defaults=dict(tb_rate=2.0, tb_burst=8.0),
    validate=_tb_validate,
    description="token bucket over check-ins: tb_rate tokens/round, "
                "burst tb_burst, one token per admitted client",
))


# ---------------------------------------------------------------------------
# adaptive — AIMD cohort sizing against the round deadline
# ---------------------------------------------------------------------------

def _ad_init(opts):
    del opts
    return dict(cohort=0.0)       # 0 == "start at cohort_max"


def _ad_admit(opts, state, stats):
    cur = state["cohort"] if state["cohort"] > 0 \
        else float(stats["cohort_max"])
    if stats["last_round_s"] > stats["target_round_s"] > 0:
        cur *= float(opts["ad_shrink"])           # missed: back off
    elif stats["queue_depth"] > int(cur):
        cur += float(opts["ad_grow"])             # headroom + pressure
    cur = min(max(cur, float(opts["ad_min"])), float(stats["cohort_max"]))
    return min(stats["queue_depth"], int(cur)), dict(state, cohort=cur)


def _ad_validate(opts):
    if not 0.0 < opts["ad_shrink"] < 1.0:
        raise ValueError(f"ad_shrink must be in (0, 1), got "
                         f"{opts['ad_shrink']}")
    if opts["ad_grow"] <= 0:
        raise ValueError("ad_grow must be > 0")
    if opts["ad_min"] < 1:
        raise ValueError("ad_min must be >= 1")


register_policy(AdmissionPolicy(
    name="adaptive",
    admit=_ad_admit,
    init=_ad_init,
    options=("ad_shrink", "ad_grow", "ad_min"),
    defaults=dict(ad_shrink=0.7, ad_grow=1.0, ad_min=1),
    validate=_ad_validate,
    description="AIMD effective-cohort sizing against target_round_s "
                "(shrink multiplicatively on a miss, grow additively "
                "under queue pressure)",
))
