"""repro.serve — production round service (DESIGN.md §12).

The seventh registry subsystem: a `Coordinator` drives the simulator one
round at a time from a `ClientQueue` of simulated check-ins, with a
registered `AdmissionPolicy` sizing each cohort and a deadline policy
cutting stragglers — all folded into the Horvitz-Thompson weights via
the "external" sampler/fault shims, so Eq. 10-12 stays unbiased with no
estimator change.
"""
from repro.serve.admission import (  # noqa: F401
    AdmissionPolicy, get_policy, register_policy, registered_policies,
    resolve_opts,
)
from repro.serve.coordinator import (  # noqa: F401
    Coordinator, make_serve_config,
)
from repro.serve.queue import ClientQueue  # noqa: F401
