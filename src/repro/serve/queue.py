"""Host-side client queue for the serve coordinator (DESIGN.md §12.1).

Simulates the population of M clients a real FL server faces: each round
(one `tick`), clients flip availability according to a registered
`FaultModel`'s trace — the SAME process the in-jit fault plans draw from,
so "the world the coordinator sees" and "the world the simulator injects"
share one model registry — and available clients check in with
probability `checkin_rate`.  Checked-in clients wait FIFO until admitted;
a client whose availability flips off while queued departs (a real
device going offline mid-wait).

Capacity heterogeneity rides the straggler model's latency law: client u
runs at mean latency `mu_u = lat_mean * (1 + lat_skew * span_u)`
(`faults._straggler_means`), and a round's realized latency is
`mu_u * Exp(1)` — which gives the deadline policy the closed-form
survival probability `s_u = 1 - exp(-T / mu_u)` it folds into the HT
weights (faults.py's straggler model, DESIGN.md §9.2).

Everything here is host-side numpy + eager jax on small (M,) vectors;
nothing enters the round jit.  State is JSON-serializable via
`state_dict`/`load_state_dict` so a serve checkpoint restores the queue
mid-trace (same availability bits, same rng stream, same waiting line).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.fed import faults


class ClientQueue:
    """FIFO check-in queue over a FaultModel-driven availability trace.

    avail:      registered fault-model name driving availability ("none"
                — always on, "markov" — the on/off chain, "dropout" —
                i.i.d. per-round presence) with `avail_opts` resolved by
                the model's own option contract.
    lat_mean /
    lat_skew:   straggler-law per-client mean latencies (seconds of
                simulated client compute per round).
    checkin_rate: probability an available, un-queued client checks in
                at a given tick.
    """

    def __init__(self, n_clients: int, avail: str = "markov",
                 avail_opts: dict | None = None, checkin_rate: float = 0.5,
                 lat_mean: float = 1.0, lat_skew: float = 0.5, seed: int = 0):
        if not 0.0 < checkin_rate <= 1.0:
            raise ValueError(f"checkin_rate must be in (0, 1], got "
                             f"{checkin_rate}")
        self.m = int(n_clients)
        self.fm = faults.get_fault(avail)
        self.fm_opts = faults.resolve_opts(self.fm, avail_opts)
        self.checkin_rate = float(checkin_rate)
        self._rng = np.random.default_rng(seed)
        self._seed = int(seed)
        self.tick_idx = 0
        # straggler-law latency means (exact HT survival closed form)
        self._mu = np.asarray(faults._straggler_means(
            dict(str_mean=float(lat_mean), str_skew=float(lat_skew)),
            np.arange(self.m), self.m), np.float64)
        self._fstate = None
        if self.fm.stateful:
            self._fstate = {k: np.asarray(v) for k, v in
                            self.fm.init_state(self.fm_opts, self.m).items()}
        self._queued: list[int] = []
        self._on = self._availability()

    # ------------------------------------------------------------------
    def _availability(self) -> np.ndarray:
        """(M,) float 0/1 availability for the current tick, read from
        the fault model exactly as the in-jit plan would."""
        if self.fm.plan is None:                      # "none": always on
            return np.ones((self.m,), np.float32)
        key = jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                 self.tick_idx)
        fstate = None
        if self._fstate is not None:
            fstate = {k: np.asarray(v) for k, v in self._fstate.items()}
        plan = self.fm.plan(self.fm_opts, fstate, key,
                            np.arange(self.m), self.m)
        return np.asarray(plan["alive"], np.float32)

    def tick(self):
        """Advance one round: evolve availability, drop departed queued
        clients, draw new check-ins.  Returns the number of fresh
        check-ins this tick."""
        self.tick_idx += 1
        if self.fm.step is not None:
            key = jax.random.fold_in(
                jax.random.PRNGKey(self._seed ^ 0x5E12), self.tick_idx)
            self._fstate = {
                k: np.asarray(v) for k, v in
                self.fm.step(self.fm_opts, self._fstate, key).items()}
        self._on = self._availability()
        # departures: queued clients whose device went offline
        self._queued = [u for u in self._queued if self._on[u] > 0]
        in_q = np.zeros((self.m,), bool)
        if self._queued:
            in_q[np.asarray(self._queued)] = True
        eligible = (self._on > 0) & ~in_q
        coins = self._rng.random(self.m) < self.checkin_rate
        fresh = np.flatnonzero(eligible & coins)
        self._rng.shuffle(fresh)          # arrival order, not id order
        self._queued.extend(int(u) for u in fresh)
        return len(fresh)

    def admit(self, n: int) -> list[int]:
        """Pop the n oldest check-ins (FIFO)."""
        n = max(0, min(int(n), len(self._queued)))
        out, self._queued = self._queued[:n], self._queued[n:]
        return out

    def latencies(self, ids) -> np.ndarray:
        """Realized round latency per admitted client: mu_u * Exp(1)."""
        ids = np.asarray(ids, np.int64)
        return self._mu[ids] * self._rng.exponential(size=ids.shape)

    def survival(self, ids, deadline_s: float) -> np.ndarray:
        """Exact P(latency <= deadline) per client (exponential law)."""
        ids = np.asarray(ids, np.int64)
        return 1.0 - np.exp(-float(deadline_s) / self._mu[ids])

    @property
    def depth(self) -> int:
        return len(self._queued)

    @property
    def available_frac(self) -> float:
        return float(np.mean(self._on))

    # ------------------------------------------------------------------
    # checkpointing (serve sidecar, json)
    # ------------------------------------------------------------------
    def state_dict(self) -> dict:
        return dict(
            tick_idx=self.tick_idx,
            queued=list(self._queued),
            fstate=None if self._fstate is None else
            {k: np.asarray(v).tolist() for k, v in self._fstate.items()},
            rng=self._rng.bit_generator.state)

    def load_state_dict(self, sd: dict):
        self.tick_idx = int(sd["tick_idx"])
        self._queued = [int(u) for u in sd["queued"]]
        if sd.get("fstate") is not None:
            self._fstate = {k: np.asarray(v, np.float32)
                            for k, v in sd["fstate"].items()}
        self._rng.bit_generator.state = sd["rng"]
        self._on = self._availability()
