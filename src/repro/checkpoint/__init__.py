from repro.checkpoint.ckpt import (  # noqa: F401
    latest_step, read_meta, restore, restore_sim, restore_step, save,
    save_sim, save_step,
)
