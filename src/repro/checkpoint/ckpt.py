"""Msgpack-based pytree checkpointing (no external deps beyond msgpack).

Layout: <dir>/<step>.ckpt — a msgpack map {flat_key: {dtype, shape, data}}
plus a '_meta' entry.  Keys are '/'-joined tree paths, so any nesting of
dicts/lists/namedtuples round-trips.  Arrays are raw little-endian bytes.
"""
from __future__ import annotations

import os
import re

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, meta: dict | None = None):
    flat = _flatten(tree)
    payload = {k: dict(dtype=str(v.dtype), shape=list(v.shape),
                       data=v.tobytes())
               for k, v in flat.items()}
    payload["_meta"] = meta or {}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)          # atomic publish


def _read_payload(path: str):
    with open(path, "rb") as f:
        return msgpack.unpackb(f.read(), raw=False)


def restore(path: str, like, payload=None):
    """Restore into the structure of `like` (a template pytree).  An
    already-decoded `payload` (from `_read_payload`) skips the file read —
    callers that validate meta first reuse one decode (restore_sim)."""
    if payload is None:
        payload = _read_payload(path)
    payload = dict(payload)
    meta = payload.pop("_meta", {})
    flat_like = _flatten(like)
    missing = set(flat_like) - set(payload)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    restored = {}
    for k, spec in payload.items():
        arr = np.frombuffer(spec["data"], dtype=np.dtype(spec["dtype"]))
        restored[k] = jnp.asarray(arr.reshape(spec["shape"]))
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        new_leaves.append(restored[key])
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves), meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"(\d+)\.ckpt", f))]
    return max(steps) if steps else None


def save_step(directory: str, step: int, tree, meta=None, keep: int = 3):
    save(os.path.join(directory, f"{step}.ckpt"), tree,
         dict(meta or {}, step=step))
    # retention
    steps = sorted(int(re.fullmatch(r"(\d+)\.ckpt", f).group(1))
                   for f in os.listdir(directory)
                   if re.fullmatch(r"\d+\.ckpt", f))
    for s in steps[:-keep]:
        os.remove(os.path.join(directory, f"{s}.ckpt"))


def _step_path(directory: str, step: int | None) -> str:
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    return os.path.join(directory, f"{step}.ckpt")


def restore_step(directory: str, like, step: int | None = None):
    return restore(_step_path(directory, step), like)


def read_meta(directory: str, step: int | None = None) -> dict:
    """Read a checkpoint's meta dict without restoring its tree."""
    return _read_payload(_step_path(directory, step)).get("_meta", {})


# ---------------------------------------------------------------------------
# FL simulator checkpointing: params + ALL per-client method/comm state
# ---------------------------------------------------------------------------

def save_sim(directory: str, sim, meta=None, keep: int = 3):
    """Checkpoint a `fed.Simulator` at its current round.

    Persists the params together with the full state dict the method's
    `state_spec()` declares (fed/api.py) — every per-client and global
    field (FedNCV alphas, SCAFFOLD c_u/c_global, personal heads, FedNCV+
    h/h_sum, FedGLOMO momenta) plus the comm codec's error-feedback
    residuals (`ef`) and the cohort sampler's tables (`sampler`:
    importance EMA norms, similarity sketches/ages — DESIGN.md §8) and the
    fault model's availability state (`faults`: Markov on/off bits —
    DESIGN.md §9) — so a restored run continues the exact trajectory,
    compression, selection and availability state included.  Nothing here
    is per-method, per-sampler or per-fault-model: anything registered
    through `fed.api`/`fed.sampling`/`fed.faults` checkpoints correctly
    by construction.  The meta records the method/codec/sampler/
    aggregator/fault/store names and state keys for restore-time
    validation.

    The state store (fed/store.py §11) is transparent here: under
    `store="host"` the per-client tables are checkpointed from their host
    (numpy) views with the same flat keys as the device store's arrays,
    so the on-disk format is store-independent.

    Async pipelines (`staleness = K >= 1`, DESIGN.md §12): the in-flight
    pending cohort(s) — the depth-K ring — are serialized under the
    "pipeline" subtree, so a mid-pipeline crash restarts on the exact
    trajectory instead of dropping K rounds of issued work.  The meta
    records `staleness` and the in-flight count for restore-time shape
    validation; a sync checkpoint simply has no pipeline entry.
    """
    state = sim._get_state()
    tree = dict(params=sim.params, state=state)
    meta_d = dict(meta or {}, round_idx=sim.round_idx,
                  method=sim.fl.method, codec=sim.fl.codec,
                  sampler=sim.fl.sampler,
                  aggregator=sim.fl.aggregator, fault=sim.fl.fault,
                  store=sim.fl.store, staleness=sim.fl.staleness,
                  state_keys=sorted(state))
    # mesh layout is recorded for provenance only: the mesh-parity
    # contract (DESIGN.md §6, §13) makes the trajectory placement-
    # independent, so a 2-d-mesh checkpoint restores onto any mesh
    # (including none) and continues identically
    if getattr(sim, "mesh", None) is not None:
        meta_d["mesh"] = {str(k): int(v) for k, v in sim.mesh.shape.items()}
    pipe = sim.pipeline_state() if hasattr(sim, "pipeline_state") else None
    if pipe is not None:
        tree["pipeline"] = pipe
        # host rings may be mid-warmup (fewer than K entries); the device
        # carries are always full-shaped once they exist
        meta_d["pipeline_inflight"] = (len(pipe["ring"])
                                       if "pidx" in pipe
                                       else max(1, sim.fl.staleness))
    save_step(directory, sim.round_idx, tree, meta_d, keep=keep)


def restore_sim(directory: str, sim, step: int | None = None):
    """Restore a `save_sim` checkpoint into `sim` (must be configured with
    the same FLConfig, codec included — validated against the checkpoint
    meta).  Returns the checkpoint meta.

    Async pipelines: a checkpoint carrying a "pipeline" subtree restores
    the in-flight pending ring onto the simulator, so the resumed run
    continues the exact pre-crash trajectory (DESIGN.md §12).  Legacy
    checkpoints (pre-ring format, or saved before the pipeline warmed up)
    have no pipeline entry and restore with a fresh bubble — the
    historical behavior."""
    path = _step_path(directory, step)
    payload = _read_payload(path)           # one read + decode
    # validate method/codec/state-layout compatibility BEFORE the
    # structural restore, so a mismatch reports the configuration error,
    # not a low-level missing-key failure
    saved = payload.get("_meta", {})
    # strategy names recorded in the meta must exist in THIS build's
    # registries — a checkpoint from a branch with an unregistered
    # method/sampler/aggregator/fault must fail with the roster, not with
    # a downstream shape or missing-key error
    from repro.fed import api as _api
    from repro.fed import aggregators as _aggs
    from repro.fed import faults as _faults
    from repro.fed import sampling as _sampling
    for key, roster in (("method", _api.registered_methods()),
                        ("sampler", _sampling.registered_samplers()),
                        ("aggregator", _aggs.registered_aggregators()),
                        ("fault", _faults.registered_faults())):
        have = saved.get(key)
        if have is not None and have not in roster:
            raise ValueError(
                f"checkpoint names {key}={have!r}, which is not "
                f"registered in this build — registered {key}s: "
                f"{sorted(roster)}")
    # absent meta keys: method/codec predate PR 4 and default leniently to
    # the configured value; an absent sampler (aggregator, fault) key
    # definitionally means the checkpoint was written under uniform
    # selection (the mean aggregator, no faults), so it must FAIL against
    # a different configuration here (with the configuration error)
    # instead of falling through to the state_keys mismatch below
    for key, want, absent in (("method", sim.fl.method, sim.fl.method),
                              ("codec", sim.fl.codec, sim.fl.codec),
                              ("sampler", sim.fl.sampler, "uniform"),
                              ("aggregator", sim.fl.aggregator, "mean"),
                              ("fault", sim.fl.fault, "none"),
                              # absent store key: checkpoint predates the
                              # state-store registry, i.e. it was written by
                              # (and restores as) the device store
                              ("store", sim.fl.store, "device")):
        have = saved.get(key, absent)
        if have != want:
            raise ValueError(
                f"checkpoint was saved with {key}={have!r} but the "
                f"simulator is configured with {key}={want!r}")
    want_keys = sorted(sim._get_state())
    have_keys = sorted(saved.get("state_keys", want_keys))
    if have_keys != want_keys:
        raise ValueError(
            f"checkpoint state layout {have_keys} does not match the "
            f"simulator's state_spec() layout {want_keys} (same method "
            f"name, different state fields — version skew?)")
    has_pipe = any(k.startswith("pipeline/") for k in payload
                   if k != "_meta")
    if has_pipe:
        # a serialized ring is shaped by the depth it was saved under —
        # restoring it into a different pipeline depth would mis-apply
        # in-flight cohorts, so that is a configuration error
        saved_k = saved.get("staleness")
        if saved_k is not None and saved_k != sim.fl.staleness:
            raise ValueError(
                f"checkpoint carries an in-flight pipeline saved with "
                f"staleness={saved_k} but the simulator is configured "
                f"with staleness={sim.fl.staleness}")
    like = dict(params=sim.params, state=sim._get_state())
    if has_pipe:
        like["pipeline"] = sim.pipeline_template(
            n_inflight=saved.get("pipeline_inflight"))
    tree, meta = restore(path, like, payload=payload)
    sim.params = tree["params"]
    sim._set_state(tree["state"])
    sim.round_idx = int(meta.get("round_idx", sim.round_idx))
    sim.set_pipeline_state(tree.get("pipeline"))
    # re-arm the streaming tracker at the restored round: sinks discard
    # rows the checkpoint never saw (a crash mid-chunk streams ahead of
    # the last save) and cumulative counters pick up from the last
    # surviving row, so the jsonl continues with a monotone round index
    sim._track_resume(sim.round_idx)
    return meta